//! End-to-end driver: proves all three layers compose on a real small
//! workload (DESIGN.md — deliverable (b), recorded in EXPERIMENTS.md).
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train_serve
//! ```
//!
//! 1. **Train via L2/L1**: drive the AOT-compiled fused train-step
//!    artifacts (`classifier_train_dense` / `classifier_train_bfly`,
//!    jax-lowered HLO containing the butterfly graphs) from rust over
//!    PJRT, on a synthetic classification workload, logging the loss
//!    curve for both heads.
//! 2. **Serve via L3**: install the trained weights behind the
//!    coordinator (dynamic batcher + TCP server), fire concurrent
//!    clients at both variants, and report accuracy, latency and
//!    throughput.

use anyhow::{anyhow, Result};
use butterfly_net::coordinator::{serve, BatcherConfig, Coordinator, Engine, PjrtEngine};
use butterfly_net::rng::Rng;
use butterfly_net::runtime::{RuntimeHandle, Tensor};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

/// Synthetic task matching the artifact's input dim: class-clustered
/// points pushed through tanh (same generator family as §5.1 proxies).
fn make_task(
    d_in: usize,
    classes: usize,
    per_class: usize,
    rng: &mut Rng,
) -> (Vec<Vec<f64>>, Vec<usize>) {
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let protos: Vec<Vec<f64>> = (0..classes).map(|_| rng.gaussian_vec(d_in, 1.0)).collect();
    for (c, p) in protos.iter().enumerate() {
        for _ in 0..per_class {
            let x: Vec<f64> = p
                .iter()
                .map(|&v| (v + rng.gaussian() * 0.6).tanh())
                .collect();
            xs.push(x);
            ys.push(c);
        }
    }
    // shuffle
    let perm = rng.permutation(ys.len());
    (
        perm.iter().map(|&i| xs[i].clone()).collect(),
        perm.iter().map(|&i| ys[i]).collect(),
    )
}

struct TrainedVariant {
    name: &'static str,
    artifact_fwd: &'static str,
    /// bound (non-batch) inputs of the forward artifact, post-training
    bound: Vec<Tensor>,
}

fn train_variant(
    rt: &RuntimeHandle,
    train_artifact: &str,
    fwd_artifact: &'static str,
    name: &'static str,
    xs: &[Vec<f64>],
    ys: &[usize],
    steps: usize,
    rng: &mut Rng,
) -> Result<(TrainedVariant, Vec<f64>)> {
    let spec = rt
        .spec(train_artifact)?
        .ok_or_else(|| anyhow!("missing {train_artifact}"))?;
    let n_in = spec.inputs.len();
    // layout: params..., readout, x, y, lr — the last three are data;
    // everything before is (trainable params + fixed int buffers).
    let n_bound = n_in - 3;
    let mut state: Vec<Tensor> = Vec::new();
    let mut rng2 = Rng::seed_from_u64(1234);
    // Butterfly weight stacks (rank-3 float inputs) are immediately
    // followed by their `keep` index buffer in the artifact layout;
    // initialise both together from a proper FJLT sample — near-zero or
    // unsigned-Hadamard gadgets would kill or skew gradients through
    // the log n multiplicative layers.
    let mut pending_keep: Option<Vec<usize>> = None;
    for (i, ts) in spec.inputs[..n_bound].iter().enumerate() {
        state.push(match ts.dtype {
            butterfly_net::runtime::Dtype::I32 => {
                let total = ts.num_elements();
                match pending_keep.take() {
                    Some(keep) => Tensor::from_indices(&keep),
                    None => Tensor::from_indices(&(0..total).map(|i| i * 2).collect::<Vec<_>>()),
                }
            }
            _ if ts.shape.len() == 3 => {
                let n = ts.shape[1] * 2;
                // the paired keep buffer follows immediately; use its
                // length as ℓ for a consistent FJLT sample
                let l = spec
                    .inputs
                    .get(i + 1)
                    .filter(|nx| nx.dtype == butterfly_net::runtime::Dtype::I32)
                    .map(|nx| nx.num_elements())
                    .unwrap_or(n / 2)
                    .max(1);
                let j = butterfly_net::butterfly::TruncatedButterfly::fjlt(n, l, &mut rng2);
                pending_keep = Some(j.keep().to_vec());
                Tensor::from_f64(&ts.shape, &j.net().flat_weights())
            }
            _ => Tensor::from_f64(&ts.shape, &rng2.gaussian_vec(ts.num_elements(), 0.07)),
        });
    }
    let x_spec = &spec.inputs[n_in - 3];
    let y_spec = &spec.inputs[n_in - 2];
    let (bs, d_in) = (x_spec.shape[0], x_spec.shape[1]);
    let classes = y_spec.shape[1];
    let lr = Tensor::scalar_f32(0.08);
    let mut losses = Vec::new();
    // which state entries are trainable (returned by the artifact)?
    let n_out_params = spec.outputs.len() - 1; // last output is loss
                                               // map: outputs[i] replaces the i-th *float* input
    let float_slots: Vec<usize> = (0..n_bound)
        .filter(|&i| !matches!(spec.inputs[i].dtype, butterfly_net::runtime::Dtype::I32))
        .collect();
    // the readout (last float slot) is fixed — the artifact returns one
    // updated tensor per *trainable* float input
    assert_eq!(float_slots.len(), n_out_params + 1, "artifact param layout");
    for step in 0..steps {
        // minibatch
        let mut xb = vec![0.0f64; bs * d_in];
        let mut yb = vec![0.0f64; bs * classes];
        for r in 0..bs {
            let i = rng.below(xs.len());
            xb[r * d_in..(r + 1) * d_in].copy_from_slice(&xs[i]);
            yb[r * classes + ys[i]] = 1.0;
        }
        let mut inputs = state.clone();
        inputs.push(Tensor::from_f64(&x_spec.shape, &xb));
        inputs.push(Tensor::from_f64(&y_spec.shape, &yb));
        inputs.push(lr.clone());
        let outs = rt.execute(train_artifact, inputs)?;
        for (oi, &slot) in float_slots.iter().take(n_out_params).enumerate() {
            state[slot] = outs[oi].clone();
        }
        let loss = outs[n_out_params].to_scalar()?;
        losses.push(loss);
        if step % 20 == 0 {
            println!("  [{name}] step {step:>4}: loss {loss:.4}");
        }
    }
    // forward artifact shares the same bound inputs (params + readout),
    // minus nothing: fwd inputs = params..., readout, x
    let fwd_spec = rt
        .spec(fwd_artifact)?
        .ok_or_else(|| anyhow!("missing {fwd_artifact}"))?;
    let bound = state[..fwd_spec.inputs.len() - 1].to_vec();
    Ok((
        TrainedVariant {
            name,
            artifact_fwd: fwd_artifact,
            bound,
        },
        losses,
    ))
}

fn main() -> Result<()> {
    let rt = RuntimeHandle::spawn("artifacts")
        .map_err(|e| anyhow!("{e:#}\nrun `make artifacts` first"))?;
    let spec = rt
        .spec("classifier_train_dense")?
        .ok_or_else(|| anyhow!("artifacts incomplete"))?;
    let d_in = spec.inputs[spec.inputs.len() - 3].shape[1];
    let classes = spec.inputs[spec.inputs.len() - 2].shape[1];
    println!("== e2e: train both heads via PJRT artifacts (d_in={d_in}, classes={classes}) ==");
    let mut rng = Rng::seed_from_u64(0);
    let (xs, ys) = make_task(d_in, classes, 40, &mut rng);
    let steps = 120;
    let (dense, dense_losses) = train_variant(
        &rt,
        "classifier_train_dense",
        "classifier_fwd_dense",
        "dense",
        &xs,
        &ys,
        steps,
        &mut rng,
    )?;
    let (bfly, bfly_losses) = train_variant(
        &rt,
        "classifier_train_bfly",
        "classifier_fwd_bfly",
        "butterfly",
        &xs,
        &ys,
        steps,
        &mut rng,
    )?;
    println!(
        "loss: dense {:.4} → {:.4} | butterfly {:.4} → {:.4}",
        dense_losses[0],
        dense_losses.last().unwrap(),
        bfly_losses[0],
        bfly_losses.last().unwrap()
    );

    // ---- serve both trained variants through the L3 coordinator --------
    println!("\n== e2e: serve trained heads behind the dynamic batcher ==");
    let mut coordinator = Coordinator::new();
    let bcfg = BatcherConfig {
        max_batch: 32,
        max_wait: std::time::Duration::from_millis(1),
        queue_cap: 4096,
        workers: 2,
        ..BatcherConfig::default()
    };
    for v in [dense, bfly] {
        let engine = PjrtEngine::new(rt.clone(), v.artifact_fwd, v.bound.clone(), 0)?;
        println!(
            "  variant `{}` → {} (in {}, out {})",
            v.name,
            v.artifact_fwd,
            engine.input_dim(),
            engine.output_dim()
        );
        coordinator.register(v.name, Box::new(engine), bcfg.clone());
    }
    let coordinator = Arc::new(coordinator);
    let server = serve(Arc::clone(&coordinator), "127.0.0.1:0")?;
    let addr = server.addr;
    println!("  listening on {addr}");

    // concurrent clients measuring accuracy + latency per variant
    for variant in ["dense", "butterfly"] {
        let t0 = Instant::now();
        let mut handles = Vec::new();
        let n_clients = 4;
        let per_client = 40;
        for c in 0..n_clients {
            let xs = xs.clone();
            let ys = ys.clone();
            let variant = variant.to_string();
            handles.push(std::thread::spawn(move || -> Result<(usize, usize)> {
                let stream = TcpStream::connect(addr)?;
                let mut w = stream.try_clone()?;
                let mut r = BufReader::new(stream);
                let mut correct = 0;
                let mut total = 0;
                for i in 0..per_client {
                    let idx = (c * per_client + i) % xs.len();
                    let mut line = format!("INFER {variant}");
                    for v in &xs[idx] {
                        line.push_str(&format!(" {v}"));
                    }
                    line.push('\n');
                    w.write_all(line.as_bytes())?;
                    let mut resp = String::new();
                    r.read_line(&mut resp)?;
                    let toks: Vec<&str> = resp.split_whitespace().collect();
                    anyhow::ensure!(toks[0] == "OK", "bad response: {resp}");
                    let logits: Vec<f64> = toks[1..].iter().map(|t| t.parse().unwrap()).collect();
                    let pred = logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0;
                    if pred == ys[idx] {
                        correct += 1;
                    }
                    total += 1;
                }
                Ok((correct, total))
            }));
        }
        let mut correct = 0;
        let mut total = 0;
        for h in handles {
            let (c, t) = h.join().unwrap()?;
            correct += c;
            total += t;
        }
        let elapsed = t0.elapsed();
        println!(
            "  {variant:10} accuracy {:.3} | {} reqs in {:?} → {:.0} req/s",
            correct as f64 / total as f64,
            total,
            elapsed,
            total as f64 / elapsed.as_secs_f64()
        );
    }
    println!("\nmetrics:\n{}", coordinator.obs.snapshot());
    server.stop();
    rt.shutdown();
    println!("e2e OK");
    Ok(())
}
